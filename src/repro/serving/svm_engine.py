"""Streaming inference engine for compiled SVM fleets.

The compiled predict path (``repro.api``) is fast but batch-synchronous:
one caller, one batch, one dispatch.  A deployed fleet instead sees a
continuous stream of small queries from many tenants.  This engine turns
that stream back into efficient device batches:

* **Micro-batching** — requests accumulate in per-priority-class queues
  under a max-wait / max-batch policy: a batch dispatches as soon as it
  is full OR the oldest selected request has waited ``max_wait_ms``,
  trading a bounded latency floor for device efficiency.

* **Continuous batching with deadlines and priorities** — ``submit``
  takes ``deadline_ms=`` and ``priority=`` (higher = more important).
  The batch former serves priority classes in order, earliest deadline
  first within a class, and BACKFILLS across classes: any request whose
  deadline falls inside the expiry horizon is pulled into the next batch
  in EDF order regardless of class, so low-priority work about to expire
  rides along instead of dying in queue.  Non-expiring low-priority work
  can never displace higher-priority work (no priority inversion).

* **Admission control at saturation** — with ``queue_bound`` set, the
  pending-row count is hard-bounded: on overflow the engine first sheds
  already-expired queued work, then queued work of strictly lower
  priority than the incoming request (latest deadline first), else the
  incoming request itself.  Shed futures resolve with :class:`ShedError`
  (``reason`` is ``"expired"`` or ``"overflow"``).  With
  ``shed_expired=True`` the batch former also drops queued requests
  whose deadline already passed instead of wasting device cycles on
  them.  ``engine.backpressure`` is a high/low-watermark signal
  (``True`` above ``high_watermark`` pending rows until the backlog
  drains below ``low_watermark``) that ``submit`` keeps current so
  open-loop producers can throttle.

* **Padding buckets** — every dispatch is padded up to a power-of-two
  batch size (:class:`BucketPolicy`), so the engine touches at most
  ``log2(max_batch / min_bucket) + 1`` distinct shapes and each bucket
  hits ONE pre-compiled XLA program (``warmup()`` compiles them all
  eagerly; the benchmark gates ``<= 1`` compile per bucket).  Padded rows
  carry zeros and model 0 — their labels are computed and discarded.

* **Mesh-sharded dispatch** — with ``mesh=`` (a
  ``launch.mesh.make_serving_mesh``), dispatches go through the fleet's
  data-parallel :class:`~repro.api.fleet.ShardedFleetForward`:
  ``max_batch``/``min_bucket`` become PER-DEVICE bucket sizes, the
  global batch is the per-device bucket times the device count (buckets
  round to whole per-device slices; the tail padding is validity-masked
  by construction — padded rows' labels are discarded on unpack), and
  every device runs the exact single-device labels program on its row
  slice (DESIGN.md §12.1).

* **Co-batching** — the engine serves a :class:`~repro.api.FleetMachine`,
  so one dispatch carries rows for ANY mix of member models, routed by
  model index in-graph and un-padded/re-split per request on return.  A
  bare :class:`~repro.api.CompiledMachine` is wrapped into a one-member
  fleet.

* **Pipelined donated staging** — each bucket owns ``pipeline_depth + 1``
  pinned host staging buffers used round-robin, and the jitted forward
  donates the ``model_idx`` device buffer (reused for the label output,
  the alias the static analyzer verifies).  Dispatch is asynchronous:
  after launching batch *t* the batcher immediately stages batch *t+1*
  while the device computes, and only blocks on the oldest batch once
  ``pipeline_depth`` batches are in flight (default 1 = classic double
  buffering; deeper pipelines keep a mesh busy across staging gaps).

* **Observability** — per-request enqueue -> dispatch -> complete
  timestamps feed a :class:`ServingStats` accumulator with EXACT
  streaming totals (counts, rows, span, mean/max latency) and a
  fixed-size latency reservoir for percentiles, so memory stays flat
  under sustained traffic (``benchmarks/serving.py`` turns these into
  the BENCH trajectory numbers).

Usage::

    from repro.serving import SVMEngine, ShedError
    with SVMEngine(fleet, max_batch=256, max_wait_ms=2.0,
                   shed_expired=True, queue_bound=4096) as eng:
        fut = eng.submit(x_row, model="balance", deadline_ms=20.0)
        try:
            label = fut.result()
        except ShedError as e:
            ...  # request shed under overload (e.reason)
        print(eng.stats.summary())
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Optional, Union

import numpy as np

from repro.api.compiled import CompiledMachine
from repro.api.fleet import FleetMachine, compile_fleet

DEFAULT_MAX_BATCH = 256
DEFAULT_MIN_BUCKET = 8
DEFAULT_MAX_WAIT_MS = 2.0
DEFAULT_RESERVOIR = 4096


class ShedError(Exception):
    """A request was shed by admission control instead of served.

    ``reason`` is ``"expired"`` (deadline passed before dispatch) or
    ``"overflow"`` (bounded queue full and the request lost the
    priority/deadline comparison).
    """

    def __init__(self, reason: str):
        super().__init__(f"request shed ({reason})")
        self.reason = reason


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


class BucketPolicy:
    """Powers-of-two padding buckets between ``min_bucket`` and ``max_batch``.

    ``bucket_for(n)`` returns the smallest bucket holding ``n`` rows; the
    bucket set IS the engine's compiled-program set, so its size bounds
    compile count and warm-up cost.  Under a serving mesh the buckets are
    PER-DEVICE sizes; the engine multiplies by the device count.
    """

    def __init__(self, max_batch: int = DEFAULT_MAX_BATCH,
                 min_bucket: int = DEFAULT_MIN_BUCKET):
        if not (_is_pow2(max_batch) and _is_pow2(min_bucket)):
            raise ValueError(
                f"buckets must be powers of two, got min={min_bucket} "
                f"max={max_batch}")
        if min_bucket > max_batch:
            raise ValueError(f"min_bucket {min_bucket} > max_batch {max_batch}")
        self.max_batch = int(max_batch)
        self.min_bucket = int(min_bucket)
        buckets, b = [], min_bucket
        while b <= max_batch:
            buckets.append(b)
            b <<= 1
        self.buckets: tuple[int, ...] = tuple(buckets)

    def bucket_for(self, n_rows: int) -> int:
        if not 0 < n_rows <= self.max_batch:
            raise ValueError(
                f"{n_rows} rows outside (0, {self.max_batch}]")
        for b in self.buckets:
            if n_rows <= b:
                return b
        raise AssertionError("unreachable")  # pragma: no cover


class ServingStats:
    """Streaming serving telemetry with FLAT memory under sustained load.

    Totals (request/row/batch counts, stream span, mean/max latency,
    occupancy, shed counts) are EXACT streaming accumulators; latency
    and queue-wait percentiles come from a fixed-size reservoir sample
    (Algorithm R over per-request latencies), so a week of traffic costs
    the same memory as a minute.  Timestamps are ``time.perf_counter``
    seconds stamped by the engine: ``t_enqueue`` at ``submit``,
    ``t_dispatch`` at device launch, ``t_complete`` when the future
    resolves.  Queries are counted in ROWS (a k-row request is k
    queries).
    """

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR, seed: int = 0):
        self._lock = threading.Lock()
        self._capacity = int(reservoir)
        self._seed = int(seed)
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self._rng = np.random.RandomState(self._seed)
            self._n_req = 0
            self._n_rows = 0
            self._n_batches = 0
            self._sum_occupancy = 0.0
            self._t_first = math.inf
            self._t_last = -math.inf
            self._sum_lat = 0.0
            self._max_lat = 0.0
            self._sum_wait = 0.0
            self._n_deadline = 0          # requests that carried a deadline
            self._n_deadline_met = 0
            self._n_shed = 0
            self._shed_rows = 0
            self._shed_reasons: dict[str, int] = {}
            # Fixed-size reservoirs: (latency_ms, wait_ms) per request.
            self._res = np.zeros((self._capacity, 2), np.float64)
            self._res_n = 0               # requests seen by the reservoir

    # -- ingestion -----------------------------------------------------------

    def observe_batch(self, rows: int, bucket: int, requests) -> None:
        with self._lock:
            self._n_batches += 1
            self._sum_occupancy += rows / bucket
            for r in requests:
                lat_ms = (r.t_complete - r.t_enqueue) * 1e3
                wait_ms = (r.t_dispatch - r.t_enqueue) * 1e3
                self._n_req += 1
                self._n_rows += r.n_rows
                self._t_first = min(self._t_first, r.t_enqueue)
                self._t_last = max(self._t_last, r.t_complete)
                self._sum_lat += lat_ms
                self._max_lat = max(self._max_lat, lat_ms)
                self._sum_wait += wait_ms
                if r.deadline != math.inf:
                    self._n_deadline += 1
                    if r.t_complete <= r.deadline:
                        self._n_deadline_met += 1
                # Algorithm R: uniform sample over the full stream.
                if self._res_n < self._capacity:
                    self._res[self._res_n] = (lat_ms, wait_ms)
                else:
                    j = self._rng.randint(0, self._res_n + 1)
                    if j < self._capacity:
                        self._res[j] = (lat_ms, wait_ms)
                self._res_n += 1

    def observe_shed(self, request, reason: str) -> None:
        with self._lock:
            self._n_shed += 1
            self._shed_rows += request.n_rows
            self._shed_reasons[reason] = \
                self._shed_reasons.get(reason, 0) + 1

    # -- readout -------------------------------------------------------------

    @property
    def n_requests(self) -> int:
        with self._lock:
            return self._n_req

    @property
    def n_shed(self) -> int:
        with self._lock:
            return self._n_shed

    def summary(self) -> dict:
        with self._lock:
            if not self._n_req and not self._n_shed:
                return {"n_requests": 0, "n_queries": 0, "n_batches": 0}
            out = {
                "n_requests": self._n_req,
                "n_queries": self._n_rows,
                "n_batches": self._n_batches,
            }
            if self._n_shed:
                out["shed"] = {"n_requests": self._n_shed,
                               "n_queries": self._shed_rows,
                               "reasons": dict(self._shed_reasons)}
            if not self._n_req:
                return out
            span = self._t_last - self._t_first
            sample = self._res[: min(self._res_n, self._capacity)]
            lat, wait = sample[:, 0], sample[:, 1]
            out.update({
                "queries_per_s": round(self._n_rows / span, 1)
                if span > 0 else None,
                "batch_occupancy": round(
                    self._sum_occupancy / self._n_batches, 4),
                "mean_batch_rows": round(self._n_rows / self._n_batches, 2),
                "latency_ms": {
                    "p50": round(float(np.percentile(lat, 50)), 3),
                    "p95": round(float(np.percentile(lat, 95)), 3),
                    "p99": round(float(np.percentile(lat, 99)), 3),
                    "mean": round(self._sum_lat / self._n_req, 3),
                    "max": round(self._max_lat, 3),
                },
                "queue_wait_ms_p50": round(float(np.percentile(wait, 50)), 3),
                "latency_sample_n": int(min(self._res_n, self._capacity)),
            })
            if self._n_deadline:
                out["deadlines"] = {
                    "n_requests": self._n_deadline,
                    "met": self._n_deadline_met,
                    "met_rate": round(
                        self._n_deadline_met / self._n_deadline, 4),
                }
            return out


@dataclasses.dataclass
class _Request:
    x: np.ndarray            # (k, d) f32, d <= fleet.n_features
    model_idx: int
    n_rows: int
    scalar: bool             # 1-D submit -> scalar label result
    future: Future
    t_enqueue: float
    deadline: float          # absolute perf_counter s; inf = none
    priority: int            # higher = more important
    seq: int                 # submit order, FIFO tie-break
    t_dispatch: float = 0.0
    t_complete: float = 0.0

    @property
    def order(self) -> tuple:
        """Heap key inside a priority class: EDF, then FIFO."""
        return (self.deadline, self.seq)


class SVMEngine:
    """Deadline/priority continuous-batched, bucketed, co-batched serving.

    See the module docstring for the design.  The engine owns ONE batcher
    thread; ``submit`` is thread-safe and non-blocking, returning a
    :class:`concurrent.futures.Future` that resolves to the request's
    label(s) — or raises :class:`ShedError` if admission control shed it.
    Use as a context manager, or ``start()``/``stop()``.
    """

    def __init__(self, machine: Union[FleetMachine, CompiledMachine], *,
                 max_batch: int = DEFAULT_MAX_BATCH,
                 max_wait_ms: float = DEFAULT_MAX_WAIT_MS,
                 min_bucket: int = DEFAULT_MIN_BUCKET,
                 pipeline_depth: int = 1,
                 mesh=None,
                 shed_expired: bool = False,
                 queue_bound: Optional[int] = None,
                 high_watermark: Optional[int] = None,
                 low_watermark: Optional[int] = None,
                 backfill_ms: Optional[float] = None,
                 stats: Optional[ServingStats] = None,
                 decider: Optional[str] = None):
        if isinstance(machine, CompiledMachine):
            machine = compile_fleet({"default": machine},
                                    decider=decider or machine.decider)
        elif decider is not None and decider != machine.decider:
            machine = FleetMachine(machine.model_ids, machine._members,
                                   use_pallas=machine.use_pallas,
                                   interpret=machine.interpret,
                                   decider=decider)
        if not isinstance(machine, FleetMachine):
            raise TypeError(f"cannot serve a {type(machine).__name__}")
        self.fleet = machine
        self.policy = BucketPolicy(max_batch=max_batch, min_bucket=min_bucket)
        self.max_wait_s = float(max_wait_ms) * 1e-3
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got "
                             f"{pipeline_depth}")
        self.pipeline_depth = int(pipeline_depth)
        self.shed_expired = bool(shed_expired)
        self.queue_bound = None if queue_bound is None else int(queue_bound)
        if self.queue_bound is not None and self.queue_bound < 1:
            raise ValueError(f"queue_bound must be >= 1, got {queue_bound}")
        if high_watermark is None:
            high_watermark = self.queue_bound
        if low_watermark is None:
            low_watermark = None if high_watermark is None \
                else max(1, high_watermark // 2)
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        # Cross-class EDF backfill horizon: a request whose deadline falls
        # within `now + backfill` is treated as expiring and served EDF
        # regardless of priority class (default: one max-wait plus the
        # EMA batch service time, i.e. "would miss the batch after next").
        self._backfill_s = None if backfill_ms is None \
            else float(backfill_ms) * 1e-3
        self._service_ema = 0.0
        self.stats = stats if stats is not None else ServingStats()

        # Mesh-sharded forward: per-device buckets scale to whole-slice
        # global batches (DESIGN.md §12.1).
        if mesh is not None:
            self._sharded = self.fleet.shard(mesh)
            self.n_devices = self._sharded.n_devices
        else:
            self._sharded = None
            self.n_devices = 1

        d = self.fleet.n_features
        # pipeline_depth + 1 pinned host staging buffers per bucket, used
        # round-robin: with k batches in flight the batcher stages batch
        # t+k into the free buffer while the device works through t..t+k-1.
        self._staging = {
            b: [(np.zeros((b * self.n_devices, d), np.float32),
                 np.zeros((b * self.n_devices,), np.int32))
                for _ in range(self.pipeline_depth + 1)]
            for b in self.policy.buckets
        }
        self._flip = {b: 0 for b in self.policy.buckets}

        # Per-priority-class pending queues: priority -> heap of
        # (deadline, seq, request); protected by _cond with _pending_rows.
        self._cond = threading.Condition()
        self._queues: dict[int, list] = {}
        self._pending_rows = 0
        self._seq = 0
        self._backpressure = False
        self._inflight: deque = deque()
        self._carry: Optional[_Request] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "SVMEngine":
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="svm-engine-batcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue, resolve every future, join the batcher."""
        if self._thread is None:
            return
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "SVMEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self) -> None:
        """Compile every bucket's program eagerly (blocking)."""
        d = self.fleet.n_features
        for b in self.policy.buckets:
            g = b * self.n_devices
            out = self._forward(np.zeros((g, d), np.float32),
                                np.zeros((g,), np.int32))
            out.block_until_ready()

    @property
    def n_buckets(self) -> int:
        return len(self.policy.buckets)

    @property
    def max_rows(self) -> int:
        """Largest single dispatch: max bucket x device count."""
        return self.policy.max_batch * self.n_devices

    @property
    def backpressure(self) -> bool:
        """High/low-watermark overload signal: ``True`` once pending rows
        reach ``high_watermark``, until the backlog drains below
        ``low_watermark``.  Open-loop producers should throttle on it."""
        with self._cond:
            return self._backpressure

    def _forward(self, xbuf: np.ndarray, ibuf: np.ndarray):
        """Async labels dispatch; host numpy goes straight into the jit
        (single- or mesh-sharded), which commits it to the device layout."""
        if self._sharded is not None:
            return self._sharded(xbuf, ibuf)
        return self.fleet._labels_jit(xbuf, ibuf)

    # -- request ingress -----------------------------------------------------

    def submit(self, x: np.ndarray, model: Union[str, int] = 0, *,
               deadline_ms: Optional[float] = None,
               priority: int = 0) -> Future:
        """Enqueue one request (``(d,)`` row or ``(k, d)`` mini-batch).

        The returned future resolves to a scalar ``int`` label for a 1-D
        input, else an ``(k,)`` int32 array — or raises
        :class:`ShedError` if admission control shed the request.
        ``model`` is a fleet member id or index; ``deadline_ms`` is a
        relative completion deadline (``None`` = never expires);
        ``priority`` orders classes (higher = more important).
        """
        if self._thread is None:
            raise RuntimeError("engine not started (use `with SVMEngine(...)`)")
        if self._stop.is_set():
            raise RuntimeError("engine is stopping")
        x = np.asarray(x, np.float32)
        scalar = x.ndim == 1
        if scalar:
            x = x[None, :]
        if x.ndim != 2 or x.shape[1] > self.fleet.n_features:
            raise ValueError(
                f"expected (k, <= {self.fleet.n_features}) features, "
                f"got {x.shape}")
        if not 0 < x.shape[0] <= self.max_rows:
            raise ValueError(
                f"request rows {x.shape[0]} outside (0, {self.max_rows}]")
        now = time.perf_counter()
        deadline = math.inf if deadline_ms is None \
            else now + float(deadline_ms) * 1e-3
        req = _Request(x=x, model_idx=self.fleet.model_index(model),
                       n_rows=x.shape[0], scalar=scalar, future=Future(),
                       t_enqueue=now, deadline=deadline,
                       priority=int(priority), seq=0)
        with self._cond:
            req.seq = self._seq
            self._seq += 1
            if self.queue_bound is not None and \
                    self._pending_rows + req.n_rows > self.queue_bound:
                self._admit_over_bound(req, now)
            else:
                self._enqueue(req)
            if self.high_watermark is not None:
                if self._pending_rows >= self.high_watermark:
                    self._backpressure = True
                elif self._pending_rows <= self.low_watermark:
                    self._backpressure = False
            self._cond.notify()
        return req.future

    def predict(self, x: np.ndarray, model: Union[str, int] = 0):
        """Synchronous convenience wrapper: ``submit(...).result()``."""
        return self.submit(x, model).result()

    def _enqueue(self, req: _Request) -> None:
        heapq.heappush(
            self._queues.setdefault(req.priority, []),
            (req.deadline, req.seq, req))
        self._pending_rows += req.n_rows

    def _shed(self, req: _Request, reason: str) -> None:
        req.future.set_exception(ShedError(reason))
        self.stats.observe_shed(req, reason)

    def _admit_over_bound(self, req: _Request, now: float) -> None:
        """Bounded-queue admission (called with the lock held): make room
        by shedding already-expired queued work, then strictly
        lower-priority queued work (latest deadline first), else shed the
        incoming request itself."""
        self._shed_expired_locked(now)
        while self._pending_rows + req.n_rows > self.queue_bound:
            victim = self._lowest_victim_locked(below=req.priority)
            if victim is None:
                self._shed(req, "overflow")
                return
            self._remove_locked(victim)
            self._shed(victim, "overflow")
        self._enqueue(req)

    def _shed_expired_locked(self, now: float) -> None:
        for prio in list(self._queues):
            q = self._queues[prio]
            while q and q[0][0] <= now:
                _, _, r = heapq.heappop(q)
                self._pending_rows -= r.n_rows
                self._shed(r, "expired")
            if not q:
                del self._queues[prio]

    def _lowest_victim_locked(self, below: int) -> Optional[_Request]:
        """Latest-deadline request of the lowest priority class < below."""
        prios = [p for p in self._queues if p < below and self._queues[p]]
        if not prios:
            return None
        q = self._queues[min(prios)]
        return max(q, key=lambda e: (e[0], e[1]))[2]

    def _remove_locked(self, req: _Request) -> None:
        q = self._queues[req.priority]
        q.remove((req.deadline, req.seq, req))
        heapq.heapify(q)
        self._pending_rows -= req.n_rows
        if not q:
            del self._queues[req.priority]

    # -- batch former (batcher thread) ---------------------------------------

    def _horizon(self, now: float) -> float:
        """Deadlines at or before this instant count as *expiring* and are
        backfilled EDF across priority classes."""
        backfill = self._backfill_s if self._backfill_s is not None \
            else self.max_wait_s + self._service_ema
        return now + backfill

    def _select_locked(self, now: float) -> Optional[_Request]:
        """Pop the next request: expiring-EDF across classes first (ties to
        the higher priority), then highest priority class, EDF within it.
        With ``shed_expired``, already-dead work is shed instead of served.
        Call with the lock held."""
        if self.shed_expired:
            self._shed_expired_locked(now)
        if not self._queues:
            return None
        horizon = self._horizon(now)
        best_prio, expiring = None, None
        for prio, q in self._queues.items():
            head = q[0]
            if head[0] <= horizon:
                # Expiring: earliest deadline wins; tie -> higher priority.
                key = (head[0], -prio, head[1])
                if expiring is None or key < expiring[0]:
                    expiring = (key, prio)
            if best_prio is None or prio > best_prio:
                best_prio = prio
        prio = expiring[1] if expiring is not None else best_prio
        q = self._queues[prio]
        _, _, req = heapq.heappop(q)
        self._pending_rows -= req.n_rows
        if not q:
            del self._queues[prio]
        if self.low_watermark is not None and \
                self._pending_rows <= self.low_watermark:
            self._backpressure = False
        return req

    def _take(self, timeout: float) -> Optional[_Request]:
        """Blocking select: wait up to ``timeout`` for a request."""
        deadline = time.perf_counter() + timeout
        with self._cond:
            while True:
                req = self._select_locked(time.perf_counter())
                if req is not None:
                    return req
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or self._stop.is_set():
                    return None
                self._cond.wait(remaining)

    def _take_nowait(self) -> Optional[_Request]:
        with self._cond:
            return self._select_locked(time.perf_counter())

    def _pending_empty(self) -> bool:
        with self._cond:
            return not self._queues

    def _loop(self) -> None:
        max_rows = self.max_rows
        while True:
            batch: list[_Request] = []
            rows = 0
            if self._carry is not None:
                # Carried requests lead the next batch with their ORIGINAL
                # enqueue time anchoring its max-wait deadline — a large
                # request can never starve behind a stream of small ones.
                batch.append(self._carry)
                rows = self._carry.n_rows
                self._carry = None
            if not batch:
                r = self._take(timeout=0.005)
                if r is None:
                    # Idle: complete any in-flight batch, then exit once
                    # stopped and fully drained.
                    self._resolve(all_pending=True)
                    if self._stop.is_set() and self._pending_empty() \
                            and self._carry is None:
                        return
                    continue
                batch.append(r)
                rows = r.n_rows
            wait_until = batch[0].t_enqueue + self.max_wait_s
            while rows < max_rows:
                timeout = wait_until - time.perf_counter()
                # Past the deadline we stop *waiting* but still drain the
                # immediately-available backlog — a burst that outruns the
                # batcher forms full batches instead of degrading to
                # per-request dispatch.
                r = self._take(timeout) if timeout > 0 \
                    else self._take_nowait()
                if r is None:
                    break
                if rows + r.n_rows > max_rows:
                    self._carry = r       # held for the next batch
                    break
                batch.append(r)
                rows += r.n_rows
            self._dispatch(batch, rows)

    def _dispatch(self, batch: list[_Request], rows: int) -> None:
        # Whole per-device slices: bucket the PER-DEVICE row count, then
        # scale back to the global batch (n_devices = 1 when unsharded).
        per_dev = -(-rows // self.n_devices)
        bucket = self.policy.bucket_for(per_dev)
        global_rows = bucket * self.n_devices
        xbuf, ibuf = self._staging[bucket][self._flip[bucket]]
        self._flip[bucket] = (self._flip[bucket] + 1) % len(
            self._staging[bucket])
        off = 0
        for r in batch:
            k, d = r.x.shape
            xbuf[off:off + k, :d] = r.x
            if d < xbuf.shape[1]:
                xbuf[off:off + k, d:] = 0.0
            ibuf[off:off + k] = r.model_idx
            off += k
        if off < global_rows:              # padded rows: zeros, model 0
            xbuf[off:] = 0.0
            ibuf[off:] = 0
        t_disp = time.perf_counter()
        for r in batch:
            r.t_dispatch = t_disp
        try:
            labels = self._forward(xbuf, ibuf)          # async dispatch
        except Exception as e:             # pragma: no cover - defensive
            for r in batch:
                r.future.set_exception(e)
            return
        self._inflight.append((labels, batch, rows, bucket, t_disp))
        # Pipelining: block on the OLDEST batch only once the pipeline is
        # full, so staging batch t+k overlaps device compute of t..t+k-1.
        while len(self._inflight) > self.pipeline_depth:
            self._resolve()

    def _resolve(self, all_pending: bool = False) -> None:
        while self._inflight:
            labels, batch, rows, bucket, t_disp = self._inflight.popleft()
            out = np.asarray(labels)       # blocks until device completes
            t_done = time.perf_counter()
            self._service_ema = 0.8 * self._service_ema + \
                0.2 * (t_done - t_disp)
            off = 0
            for r in batch:
                lab = out[off:off + r.n_rows]
                off += r.n_rows
                r.t_complete = t_done
                r.future.set_result(int(lab[0]) if r.scalar else lab.copy())
            self.stats.observe_batch(rows, bucket, batch)
            if not all_pending:
                return
