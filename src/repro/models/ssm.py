"""Mamba2 (SSD) block — projections, depthwise causal conv, chunked scan.

The sequence mixer follows the SSD (state-space duality) formulation:
within chunks the recurrence is evaluated as masked matmuls (MXU work),
across chunks only the (heads, dh, ds) state is carried — see
repro/kernels/ssd.py for the Pallas version and the math.  Here we keep a
pure-jnp chunked implementation (`ssd_chunked`) used for lowering (the
dry-run and CPU tests) — identical math, compact HLO (lax.scan over
chunks), representative FLOPs.  Serving uses the O(1) recurrent step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_apply, dense_init, rmsnorm


def init(cfg: ModelConfig, key) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, ds, nh = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * g * ds
    return {
        # fused in_proj: [z (di), x (di), B (g*ds), C (g*ds), dt (nh)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * g * ds + nh),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_dim),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[2], di, d,
                               scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


class SSMState(NamedTuple):
    """Recurrent state for decode: ssm (b, nh, dh, ds), conv (b, w-1, conv_dim)."""
    ssm: jnp.ndarray
    conv: jnp.ndarray

    @classmethod
    def create(cls, cfg: ModelConfig, b: int, dtype=jnp.float32):
        nh, dh, ds = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * ds
        return cls(
            ssm=jnp.zeros((b, nh, dh, ds), jnp.float32),
            conv=jnp.zeros((b, cfg.conv_width - 1, conv_dim), dtype),
        )


def _split(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, g, ds, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * ds]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def _conv_causal(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv over (b, s, c) with kernel (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b)


def ssd_chunked(x, a, bmat, cmat, chunk: int = 128, init_state=None,
                unroll: bool = False):
    """Chunked SSD, pure jnp (same math as kernels/ssd.py).

    x: (b, s, nh, dh), a: (b, s, nh), bmat/cmat: (b, s, g, ds).
    Returns (y, final_state (b, nh, dh, ds)).
    """
    b, s, nh, dh = x.shape
    g, ds = bmat.shape[2], bmat.shape[3]
    rep = nh // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    # chunked views: (nc, b, L, ...)
    xc = x.reshape(b, nc, chunk, nh, dh).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(b, nc, chunk, nh).transpose(1, 0, 2, 3)
    bc = bmat.reshape(b, nc, chunk, g, ds).transpose(1, 0, 2, 3, 4)
    cc = cmat.reshape(b, nc, chunk, g, ds).transpose(1, 0, 2, 3, 4)

    s0 = init_state if init_state is not None else \
        jnp.zeros((b, nh, dh, ds), jnp.float32)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        xk, ak, bk, ck = inp          # (b,L,nh,dh) (b,L,nh) (b,L,g,ds) (b,L,g,ds)
        bk = jnp.repeat(bk, rep, axis=2)   # (b, L, nh, ds)
        ck = jnp.repeat(ck, rep, axis=2)
        cum = jnp.cumsum(ak, axis=1)       # (b, L, nh) inclusive
        total = cum[:, -1]                 # (b, nh)
        gmat = jnp.einsum("blhs,bjhs->bhlj", ck, bk)           # (b,nh,L,L)
        logdec = cum.transpose(0, 2, 1)[:, :, :, None] - \
            cum.transpose(0, 2, 1)[:, :, None, :]              # cum_l - cum_j
        dec = jnp.where(causal[None, None], jnp.exp(jnp.minimum(logdec, 0.0)), 0.0)
        y_intra = jnp.einsum("bhlj,bjhd->blhd", gmat * dec, xk)
        y_inter = jnp.einsum("blhs,bhds,blh->blhd", ck, state, jnp.exp(cum))
        w = jnp.exp(total[:, None, :] - cum)                   # (b, L, nh)
        s_new = jnp.exp(total)[:, :, None, None] * state + \
            jnp.einsum("blhd,blhs,blh->bhds", xk, bk, w)
        return s_new, (y_intra + y_inter).astype(x.dtype)

    final, ys = jax.lax.scan(step, s0, (xc, ac, bc, cc),
                             unroll=nc if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sp, nh, dh)[:, :s]
    return y, final


def apply_seq(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              init_state=None, chunk: int | None = None):
    """Full-sequence Mamba2 mixer. x: (b, s, d) -> (y, SSMState)."""
    b, s, _ = x.shape
    nh, dh, ds, g = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xbc, dt = _split(cfg, dense_apply(p["in_proj"], x))
    conv_tail = xbc[:, -(cfg.conv_width - 1):, :]
    xbc = _conv_causal(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :cfg.d_inner].reshape(b, s, nh, dh)
    bmat = xbc[..., cfg.d_inner:cfg.d_inner + g * ds].reshape(b, s, g, ds)
    cmat = xbc[..., cfg.d_inner + g * ds:].reshape(b, s, g, ds)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (b, s, nh)
    a = -jnp.exp(p["a_log"])[None, None, :] * dt                     # log decay
    xin = xs.astype(jnp.float32) * dt[..., None]
    y, s_fin = ssd_chunked(xin, a, bmat.astype(jnp.float32),
                           cmat.astype(jnp.float32),
                           chunk=chunk or 128,
                           init_state=init_state.ssm if init_state else None,
                           unroll=cfg.scan_unroll)
    y = y + xin * p["d_skip"][None, None, :, None]                   # D skip
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    state = SSMState(ssm=s_fin, conv=conv_tail)
    return dense_apply(p["out_proj"], y), state


def apply_step(cfg: ModelConfig, p: dict, x: jnp.ndarray, state: SSMState):
    """O(1) decode step. x: (b, 1, d) -> (y (b, 1, d), new state)."""
    b = x.shape[0]
    nh, dh, ds, g = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xbc, dt = _split(cfg, dense_apply(p["in_proj"], x))           # (b,1,*)
    window = jnp.concatenate([state.conv, xbc], axis=1)              # (b, w, c)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    xs = conv_out[..., :cfg.d_inner].reshape(b, nh, dh)
    bmat = conv_out[..., cfg.d_inner:cfg.d_inner + g * ds].reshape(b, g, ds)
    cmat = conv_out[..., cfg.d_inner + g * ds:].reshape(b, g, ds)
    rep = nh // g
    bmat = jnp.repeat(bmat, rep, axis=1)                             # (b, nh, ds)
    cmat = jnp.repeat(cmat, rep, axis=1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b, nh)
    decay = jnp.exp(-jnp.exp(p["a_log"])[None] * dtv)                # (b, nh)
    xin = xs.astype(jnp.float32) * dtv[..., None]
    s_new = decay[..., None, None] * state.ssm + \
        xin[..., None] * bmat[:, :, None, :]
    y = jnp.einsum("bhds,bhs->bhd", s_new, cmat) + xin * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return dense_apply(p["out_proj"], y), SSMState(ssm=s_new, conv=window[:, 1:])
