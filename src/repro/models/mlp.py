"""Feed-forward layers: gated dense MLP and sort-based capacity MoE.

The MoE dispatch avoids any (T, E, C) one-hot tensor (which would be
terabytes at kimi-k2 scale): assignments are sorted by expert id, each
expert takes a contiguous capacity-C slab of the sorted stream, and the
expert compute is ONE batched einsum (E, C, D) x (E, D, F) that maps to
MXU-dense grouped matmul.  With experts sharded over the `model` axis
(EP), XLA's SPMD partitioner materialises the token exchange as
all-to-all — the same schedule a hand-written shard_map dispatch would
use; the dry-run records it.

Tokens beyond capacity are dropped (standard GShard/MaxText semantics);
the router aux loss keeps the load balanced so drops stay rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    ModelConfig, ShardRules, activation, dense_apply, dense_init, shard,
)
from jax.sharding import PartitionSpec as P


def init_dense(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 3)
    f = d_ff or cfg.d_ff
    scale_o = 0.02 / (2 * cfg.n_layers) ** 0.5
    return {
        "wg": dense_init(ks[0], cfg.d_model, f, bias=cfg.mlp_bias),
        "wu": dense_init(ks[1], cfg.d_model, f, bias=cfg.mlp_bias),
        "wd": dense_init(ks[2], f, cfg.d_model, scale=scale_o, bias=cfg.mlp_bias),
    }


def apply_dense(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = activation(cfg, dense_apply(p["wg"], x)) * dense_apply(p["wu"], x)
    return dense_apply(p["wd"], h)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_o = 0.02 / (2 * cfg.n_layers) ** 0.5
    p = {
        "router": dense_init(ks[0], d, e),
        "wg": jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.02,
        "wu": jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.02,
        "wd": jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_o,
    }
    if cfg.n_shared_experts:
        sub = ModelConfig(**{**cfg.__dict__, "d_ff": cfg.d_ff * cfg.n_shared_experts})
        p["shared"] = init_dense(sub, ks[4], d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def apply_moe(cfg: ModelConfig, rules: ShardRules, p: dict,
              x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, aux_loss).  Sort-based top-k capacity routing."""
    if cfg.moe_groups > 1:
        return apply_moe_grouped(cfg, rules, p, x)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = dense_apply(p["router"], xf.astype(jnp.float32))       # (t, e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                           # (t, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch-style) ----
    me = probs.mean(0)                                               # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)
    ) / (t * k)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    eid = top_i.reshape(-1)                                          # (t*k,)
    tid = jnp.arange(t * k, dtype=jnp.int32) // k                    # token ids
    wgt = top_p.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tid_s, wgt_s = eid[order], tid[order], wgt[order]

    counts = jnp.zeros((e,), jnp.int32).at[eid].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    cap = max(cap, min(t, 2 * k))            # decode floor: tiny t
    if cfg.moe_two_d:
        cap = -(-cap // 128) * 128           # round up so dp divides cap
    slot = jnp.arange(cap, dtype=jnp.int32)
    take = offsets[:, None] + slot[None, :]                          # (e, cap)
    valid = slot[None, :] < counts[:, None]
    take = jnp.where(valid, jnp.minimum(take, t * k - 1), t * k)     # sentinel

    tid_pad = jnp.concatenate([tid_s, jnp.zeros((1,), jnp.int32)])
    wgt_pad = jnp.concatenate([wgt_s, jnp.zeros((1,), jnp.float32)])
    tok = tid_pad[take]                                              # (e, cap)
    w_tok = jnp.where(valid, wgt_pad[take], 0.0)                     # (e, cap)

    xe = xf[tok]                                                     # (e, cap, d)
    # EP: experts over tp.  With moe_two_d the capacity dim additionally
    # shards over dp, so the token exchange becomes a per-dp-shard
    # all-to-all instead of a full-batch all-gather (+ full all-reduce on
    # the way back) — the §Perf kimi hillclimb lever.
    ep_spec = P(rules.tp, rules.dp, None) if cfg.moe_two_d \
        else P(rules.tp, None, None)
    xe = shard(xe, ep_spec)
    h = activation(cfg, jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(xe.dtype))
    oe = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(xe.dtype))     # (e, cap, d)
    oe = shard(oe, ep_spec)
    oe = oe * w_tok[..., None].astype(oe.dtype)

    out = jnp.zeros((t, d), oe.dtype).at[tok.reshape(-1)].add(
        oe.reshape(-1, d), mode="drop"
    )
    if cfg.n_shared_experts:
        out = out + apply_dense(cfg, p["shared"], xf)
    return out.reshape(b, s, d), aux


def apply_moe_grouped(cfg: ModelConfig, rules: ShardRules, p: dict,
                      x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped dispatch (§Perf kimi hillclimb, step 2).

    Tokens are split into ``cfg.moe_groups`` groups aligned with the dp
    shards; routing, capacity and the gather/scatter all happen WITHIN a
    group, so dispatch costs no cross-dp communication.  The only
    cross-device exchange left is the g-major -> e-major reshard of the
    (G, E, C, D) expert batch — exactly the canonical MoE all-to-all —
    which XLA's SPMD partitioner emits from the sharding constraints.

    With capacity_factor high enough that nothing drops, this computes
    the SAME function as apply_moe (tested in test_models_smoke).
    """
    b, s, d = x.shape
    e, k, g = cfg.n_experts, cfg.top_k, cfg.moe_groups
    t = b * s
    assert t % g == 0, (t, g)
    tg = t // g
    xg = x.reshape(g, tg, d)
    xg = shard(xg, P(rules.dp, None, None))

    logits = dense_apply(p["router"], xg.astype(jnp.float32))        # (g,tg,e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                           # (g,tg,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = probs.reshape(t, e).mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    cap = int(max(1, round(tg * k / e * cfg.capacity_factor)))
    cap = max(cap, min(tg, 2 * k))

    def dispatch_one(eid_flat, wgt_flat):
        """Per-group sort dispatch: -> (tok (e,cap), wgt (e,cap))."""
        order = jnp.argsort(eid_flat, stable=True)
        tid_s = (order // k).astype(jnp.int32)
        wgt_s = wgt_flat[order]
        counts = jnp.zeros((e,), jnp.int32).at[eid_flat].add(1)
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
        slot = jnp.arange(cap, dtype=jnp.int32)
        take = offsets[:, None] + slot[None, :]
        valid = slot[None, :] < counts[:, None]
        take = jnp.where(valid, jnp.minimum(take, tg * k - 1), tg * k)
        tid_pad = jnp.concatenate([tid_s, jnp.zeros((1,), jnp.int32)])
        wgt_pad = jnp.concatenate([wgt_s, jnp.zeros((1,), jnp.float32)])
        return tid_pad[take], jnp.where(valid, wgt_pad[take], 0.0)

    tok, w_tok = jax.vmap(dispatch_one)(
        top_i.reshape(g, tg * k), top_p.reshape(g, tg * k))          # (g,e,cap)

    xe = jax.vmap(lambda xg_, tok_: xg_[tok_])(xg, tok)              # (g,e,cap,d)
    # group-major -> expert-major reshard: THE MoE all-to-all
    xe = shard(xe, P(rules.dp, rules.tp, None, None))
    h = activation(cfg, jnp.einsum("gecd,edf->gecf", xe,
                                   p["wg"].astype(xe.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(xe.dtype))
    oe = jnp.einsum("gecf,efd->gecd", h, p["wd"].astype(xe.dtype))
    oe = shard(oe, P(rules.dp, rules.tp, None, None))
    oe = oe * w_tok[..., None].astype(oe.dtype)

    out = jax.vmap(
        lambda oe_, tok_: jnp.zeros((tg, d), oe.dtype).at[
            tok_.reshape(-1)].add(oe_.reshape(-1, d), mode="drop")
    )(oe, tok)                                                       # (g,tg,d)
    out = shard(out, P(rules.dp, None, None))
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + apply_dense(cfg, p["shared"], x.reshape(t, d)).reshape(
            b, s, d)
    return out, aux
