"""Attention layer: GQA + RoPE + (optional) sliding window, three paths.

* ``attend_full``  — reference softmax(QK^T)V; fine for short sequences.
* ``attend_scan``  — flash-style online softmax over KV blocks via
  ``lax.scan`` in pure JAX: the S x S score matrix never materializes in
  HBM (one (sq_blk, bk) tile at a time), which is what keeps the 32k
  prefill memory-roofline sane in the dry-run.  Mirrors the Pallas kernel
  (repro.kernels.flash_attention) numerically; the Pallas path is used on
  real TPUs, this path lowers everywhere.
* ``attend_decode`` — 1 query token against a KV cache (ring buffer for
  SWA layers), no softmax trick needed ((1, S) logits are tiny).

All paths share the GQA grouping: q heads (b, hq, s, dh) fold to
(b, hkv, group, s, dh) so K/V are never repeated in memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_apply, dense_init, rope

NEG_INF = -1e30


def init(cfg: ModelConfig, key) -> dict:
    dh = cfg.head_dim
    ks = jax.random.split(key, 4)
    scale_o = 0.02 / (2 * cfg.n_layers) ** 0.5
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * dh, cfg.d_model,
                         scale=scale_o, bias=cfg.out_bias),
    }


def qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions: jnp.ndarray):
    """x: (b, s, d) -> q (b, hq, s, dh), k/v (b, hkv, s, dh), rope applied."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)
    k = dense_apply(p["wk"], x).reshape(b, s, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    v = dense_apply(p["wv"], x).reshape(b, s, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group(q, hkv):
    b, hq, s, dh = q.shape
    return q.reshape(b, hkv, hq // hkv, s, dh)


def attend_full(q, k, v, causal: bool = True, window=None, q_offset: int = 0):
    """(b, hq, sq, dh) x (b, hkv, skv, dh) -> (b, hq, sq, dh)."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    qg = _group(q, hkv)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k).astype(jnp.float32)
    logits /= float(dh) ** 0.5
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, hq, sq, dh)


def attend_scan(q, k, v, causal: bool = True, window=None,
                block: int = 1024, q_offset: int = 0, unroll: bool = False):
    """Online-softmax over KV blocks; peak memory O(sq * block) per head."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if skv % block:
        pad = block - skv % block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nb = k.shape[2] // block
    qg = _group(q, hkv).astype(jnp.float32) / float(dh) ** 0.5
    kb = k.reshape(b, hkv, nb, block, dh).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nb, block, dh).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)[:, None] + q_offset

    def step(carry, inp):
        m, l, acc = carry
        ki, kblk, vblk = inp
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kblk.astype(jnp.float32))
        kpos = ki * block + jnp.arange(block)[None, :]
        mask = kpos < skv
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new) * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    group = hq // hkv
    m0 = jnp.full((b, hkv, group, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros((b, hkv, group, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  (jnp.arange(nb), kb, vb),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


def attend(cfg: ModelConfig, q, k, v, causal=True, window=None, q_offset=0):
    if cfg.use_scan_attention and k.shape[2] > cfg.attn_block:
        return attend_scan(q, k, v, causal, window, cfg.attn_block, q_offset,
                           unroll=cfg.scan_unroll)
    return attend_full(q, k, v, causal, window, q_offset)


# ---------------------------------------------------------------------------
# Decode with KV cache (full or ring-buffer/SWA)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """k/v: (b, hkv, cap, dh).  For SWA layers cap == window (ring)."""
    k: jnp.ndarray
    v: jnp.ndarray
    ring: bool

    @classmethod
    def create(cls, b, hkv, cap, dh, dtype, ring=False):
        return cls(
            k=jnp.zeros((b, hkv, cap, dh), dtype),
            v=jnp.zeros((b, hkv, cap, dh), dtype),
            ring=ring,
        )


def cache_update(cache: KVCache, k_new, v_new, pos) -> KVCache:
    """Insert one token's k/v at absolute position ``pos`` (ring-aware).
    int8 caches (kv_dtype override) quantize with a fixed scale — the
    dry-run dataflow stand-in for per-head scaled KV quantization."""
    if cache.k.dtype != k_new.dtype:
        k_new = (k_new * 16.0).astype(cache.k.dtype)
        v_new = (v_new * 16.0).astype(cache.v.dtype)
    cap = cache.k.shape[2]
    slot = (pos % cap) if cache.ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=2)
    return cache._replace(k=k, v=v)


def attend_decode(cfg: ModelConfig, q, cache: KVCache, pos, window=None):
    """q: (b, hq, 1, dh) vs cache; ``pos`` is the current absolute position."""
    b, hq, _, dh = q.shape
    cap = cache.k.shape[2]
    hkv = cache.k.shape[1]
    qg = _group(q, hkv).astype(jnp.float32) / float(dh) ** 0.5
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, cache.k.astype(jnp.float32))
    slots = jnp.arange(cap)
    if cache.ring:
        # slot holds absolute position p iff p = latest write to that slot;
        # valid when the slot's position is within (pos-window, pos].
        age = (pos % cap - slots) % cap            # 0 == newest
        valid = (age <= jnp.minimum(pos, cap - 1))
        if window is not None:
            valid &= age < window
    else:
        valid = slots <= pos
        if window is not None:
            valid &= slots > pos - window
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, cache.v.astype(jnp.float32))
    return out.reshape(b, hq, 1, dh).astype(q.dtype)
