"""Shared model substrate: config, norms, rope, init, sharding helpers.

One ``ModelConfig`` covers every assigned architecture family (dense /
moe / ssm / hybrid / vlm / audio-enc-dec); family-specific fields are
simply unused elsewhere.  All shapes follow the assignment table
verbatim (src/repro/configs/<id>.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads

    # attention details
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None           # sliding-window size (SWA layers)
    global_layers: Sequence[int] = ()      # full-attention layers in SWA stacks
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    act: str = "silu"                      # silu | gelu
    parallel_block: bool = False           # attn + mlp in parallel (command-r)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_two_d: bool = False     # shard MoE dispatch capacity over dp too
    moe_groups: int = 1         # GShard-style per-group (per-dp-shard) routing
    kv_dtype: str = ""          # serve-cache dtype override (e.g. 'int8')

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0                     # 0 -> derived from d_inner/ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4

    # enc-dec (whisper)
    n_enc_layers: int = 0                  # 0 -> decoder-only
    enc_seq_divisor: int = 2               # stub conv stride: frames = S / 2
    dec_seq_divisor: int = 8               # decoder tokens = S / 8

    # vlm stub frontend
    n_patches: int = 0                     # prepended precomputed patch embeds

    # training-time details
    dtype: str = "bfloat16"
    remat: str = "full"                    # none | full | dots
    attn_block: int = 1024                 # kv block for scan-attention
    use_scan_attention: bool = True        # online-softmax lax.scan attention
    scan_unroll: bool = False              # unroll scans (analysis lowering)

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        Hq, Hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D
        mlp = 3 * D * F
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * self.d_ff + D * self.n_experts
            mlp += self.n_shared_experts * 3 * D * self.d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ds, g = self.d_inner, self.ssm_state, self.ssm_groups
            nh = self.n_ssm_heads
            ssm = D * (2 * di + 2 * g * ds + nh) + di * D + 3 * nh
        blocks = {
            "dense": attn + mlp, "vlm": attn + mlp, "audio": attn + mlp,
            "moe": attn + mlp,
            "ssm": ssm,
            "hybrid": attn + mlp + ssm,
        }[self.family]
        total = L * blocks + 2 * V * D  # embed + unembed
        if self.family == "audio":  # encoder stack + cross-attn in decoder
            total += self.n_enc_layers * (attn + mlp) + L * attn
        return int(total)

    def active_param_count(self) -> int:
        """Active-per-token params (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        D, V, L = self.d_model, self.vocab_size, self.n_layers
        Hq, Hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * Hq * dh + 2 * D * Hkv * dh + Hq * dh * D
        mlp = (self.top_k + self.n_shared_experts) * 3 * D * self.d_ff \
            + D * self.n_experts
        return int(L * (attn + mlp) + 2 * V * D)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Axis names for the logical parallel dims (DESIGN.md §6).

    dp: data-parallel mesh axes (('pod','data') multi-pod, ('data',) single)
    tp: tensor-parallel axis   ('model')
    fsdp: axis params/optimizer are additionally sharded over (ZeRO-3); None
          replicates params over dp.
    sp: sequence-parallel axis for long-context activations; None disables.
    """

    dp: tuple = ("data",)
    tp: Optional[str] = "model"
    fsdp: Optional[str] = "data"
    sp: Optional[str] = None

    def act(self, *rest) -> P:
        """Activation spec: batch over dp, then given axes."""
        return P(self.dp, *rest)


def shard(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


def norm_apply(cfg: ModelConfig, x, p):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p.get("bias"))


def norm_init(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def activation(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x: (..., s, dh), positions: (s,) or (b, s)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dims: x is (b, h, s, dh); ang (s, half) or (b,s,half)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, scale: float = 0.02,
               bias: bool = False, dtype=jnp.float32):
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x):
    w = p["w"]
    y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y
