"""LM substrate: attention, MLP/MoE, SSM, and per-family model assembly."""
from repro.models import attention, common, mlp, ssm, transformer  # noqa: F401
