"""Model assembly for every assigned architecture family.

Train/prefill paths scan over a STACKED layer pytree (``lax.scan`` =>
O(1) compile time in depth, the only sane choice at 61-64 layers), with
configurable remat.  Decode paths unroll layers (heterogeneous caches —
ring buffers for SWA layers, full caches for global layers, recurrent
states for SSM — don't stack).

Families:
  dense / vlm      pre-norm GQA + gated MLP (parallel block for command-r)
  moe              GQA + sort-based capacity MoE (+ shared experts)
  ssm              Mamba2 mixer only
  hybrid (hymba)   parallel attn & mamba heads sharing the residual stream,
                   SWA everywhere except cfg.global_layers
  audio (whisper)  encoder (non-causal) + decoder (causal + cross-attn)

The vlm/audio modality frontends are STUBS per the assignment: inputs
arrive as precomputed patch/frame embeddings of width d_model.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ModelConfig, ShardRules, dense_apply, norm_apply, norm_init, shard,
)

# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "moe", "hybrid", "audio"):
        p["norm1"] = norm_init(cfg, cfg.d_model)
        p["attn"] = attn.init(cfg, ks[0])
    if cfg.family in ("dense", "vlm", "hybrid", "audio"):
        p["norm2"] = norm_init(cfg, cfg.d_model)
        p["mlp"] = mlp_mod.init_dense(cfg, ks[1])
    if cfg.family == "moe":
        p["norm2"] = norm_init(cfg, cfg.d_model)
        p["moe"] = mlp_mod.init_moe(cfg, ks[2])
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "ssm":
            p["norm1"] = norm_init(cfg, cfg.d_model)
        p["ssm"] = ssm_mod.init(cfg, ks[3])
    return p


def _init_cross_block(cfg: ModelConfig, key) -> dict:
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    p = _init_block(cfg, key)
    k = jax.random.fold_in(key, 7)
    p["norm_x"] = norm_init(cfg, cfg.d_model)
    p["xattn"] = attn.init(cfg, k)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    k_embed, k_unembed, k_layers, k_enc = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": jax.random.normal(
            k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            k_unembed, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02

    block_init = _init_cross_block if cfg.family == "audio" else _init_block
    params["layers"] = jax.vmap(
        lambda k: block_init(cfg, k))(jax.random.split(k_layers, cfg.n_layers))

    if cfg.family == "audio":
        params["enc_layers"] = jax.vmap(
            lambda k: _init_block(
                dataclasses.replace(cfg, family="dense"), k)
        )(jax.random.split(k_enc, cfg.n_enc_layers))
        params["enc_norm"] = norm_init(cfg, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Blocks (full-sequence path)
# ---------------------------------------------------------------------------


def _attn_sub(cfg, rules, p, x, positions, causal=True, window=None,
              kv_override=None):
    h = norm_apply(cfg, x, p["norm1"] if "norm1" in p else p["norm_x"])
    q, k, v = attn.qkv(cfg, p["attn"] if "attn" in p else p["xattn"],
                       h, positions)
    if kv_override is not None:
        k, v = kv_override
    q = shard(q, rules.act(rules.tp, None, None))
    out = attn.attend(cfg, q, k, v, causal=causal, window=window)
    b, hq, s, dh = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    proj = dense_apply((p["attn"] if "attn" in p else p["xattn"])["wo"], out)
    return proj, (k, v)


def block_forward(cfg: ModelConfig, rules: ShardRules, p: dict,
                  x: jnp.ndarray, positions, is_global=None, causal=True):
    """One layer, full sequence.  Returns (x, aux) with aux carrying
    (kv or ssm state, moe aux loss) for prefill/metrics."""
    aux: dict[str, Any] = {}
    window = cfg.window
    if is_global is not None and window is not None:
        # scanned per-layer flag: global layers disable the window by
        # setting it beyond the sequence — mask math stays shape-static.
        window = None  # handled inside attend via mask below

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        a_out, kv = _attn_sub(cfg, rules, p, x, positions,
                              causal=causal, window=cfg.window)
        aux["kv"] = kv
        if cfg.parallel_block:
            m_out = mlp_mod.apply_dense(
                cfg, p["mlp"], norm_apply(cfg, x, p["norm1"]))
            x = x + a_out + m_out
        else:
            x = x + a_out
            h = norm_apply(cfg, x, p["norm2"])
            if cfg.family == "moe":
                m_out, moe_aux = mlp_mod.apply_moe(cfg, rules, p["moe"], h)
                aux["moe_aux"] = moe_aux
            else:
                m_out = mlp_mod.apply_dense(cfg, p["mlp"], h)
            x = x + m_out

    elif cfg.family == "ssm":
        h = norm_apply(cfg, x, p["norm1"])
        y, state = ssm_mod.apply_seq(cfg, p["ssm"], h)
        aux["ssm"] = state
        x = x + y

    elif cfg.family == "hybrid":
        h = norm_apply(cfg, x, p["norm1"])
        q, k, v = attn.qkv(cfg, p["attn"], h, positions)
        # per-layer global flag folds into the mask via a dynamic window:
        # SWA layers use cfg.window, global layers effectively unbounded.
        eff_window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.window)) if is_global is not None \
            else cfg.window
        a_out = _attend_dyn_window(cfg, q, k, v, eff_window)
        b, hq, s, dh = a_out.shape
        a_out = a_out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
        a_out = dense_apply(p["attn"]["wo"], a_out)
        y, state = ssm_mod.apply_seq(cfg, p["ssm"], h)
        aux["kv"] = (k, v)
        aux["ssm"] = state
        x = x + 0.5 * (a_out + y)            # parallel heads, mean-combined
        x = x + mlp_mod.apply_dense(cfg, p["mlp"], norm_apply(cfg, x, p["norm2"]))

    x = shard(x, rules.act(None, None))
    return x, aux


def _attend_dyn_window(cfg, q, k, v, window):
    """Attention where the window size is a traced scalar (scanned layers)."""
    if isinstance(window, int) or window is None:
        return attn.attend(cfg, q, k, v, causal=True, window=window)
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if cfg.use_scan_attention and skv > cfg.attn_block:
        return _scan_dyn_window(cfg, q, k, v, window)
    qg = q.reshape(b, hkv, hq // hkv, sq, dh).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) / float(dh) ** 0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, attn.NEG_INF)
    probs = jax.nn.softmax(logits, -1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, hq, sq, dh)


def _scan_dyn_window(cfg, q, k, v, window):
    """Dynamic-window version of attention.attend_scan (traced window)."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    block = cfg.attn_block
    if skv % block:
        pad = block - skv % block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nb = k.shape[2] // block
    qg = q.reshape(b, hkv, hq // hkv, sq, dh).astype(jnp.float32) / float(dh) ** 0.5
    kb = k.reshape(b, hkv, nb, block, dh).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nb, block, dh).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)[:, None]

    def step(carry, inp):
        m, l, acc = carry
        ki, kblk, vblk = inp
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kblk.astype(jnp.float32))
        kpos = ki * block + jnp.arange(block)[None, :]
        mask = (kpos < skv) & (kpos <= qpos) & (kpos > qpos - window)
        logits = jnp.where(mask[None, None, None], logits, attn.NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
        p_ = jnp.exp(logits - m_new) * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l = l * corr + p_.sum(-1, keepdims=True)
        acc = acc * corr + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p_, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    g = hq // hkv
    m0 = jnp.full((b, hkv, g, sq, 1), attn.NEG_INF, jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, jnp.zeros_like(m0),
               jnp.zeros((b, hkv, g, sq, dh), jnp.float32)),
        (jnp.arange(nb), kb, vb), unroll=nb if cfg.scan_unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def run_stack(cfg: ModelConfig, rules: ShardRules, layers, x, positions,
              causal=True, collect_kv=False):
    """lax.scan over the stacked layer tree. Returns (x, stacked aux)."""
    is_global = None
    if cfg.family == "hybrid":
        flags = jnp.zeros((cfg.n_layers,), bool)
        if cfg.global_layers:
            flags = flags.at[jnp.asarray(cfg.global_layers)].set(True)
        is_global = flags

    def body(x, inp):
        p, flag = inp
        x, aux = block_forward(cfg, rules, p, x, positions,
                               is_global=flag, causal=causal)
        keep = {}
        if collect_kv and "kv" in aux:
            keep["kv"] = aux["kv"]
        if collect_kv and "ssm" in aux:
            keep["ssm"] = aux["ssm"]
        if "moe_aux" in aux:
            keep["moe_aux"] = aux["moe_aux"]
        return x, keep

    body = _remat(cfg, body)
    flags_in = is_global if is_global is not None \
        else jnp.zeros((cfg.n_layers,), bool)
    x, stacked = jax.lax.scan(body, x, (layers, flags_in),
                              unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return x, stacked


# ---------------------------------------------------------------------------
# Full forward passes
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, tokens):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    return x


def logits_from_x(cfg: ModelConfig, params, x, rules: ShardRules):
    x = norm_apply(cfg, x, params["final_norm"])
    unembed = params.get("unembed", params["embed"])
    logits = x @ unembed.astype(x.dtype).T
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, rules.act(None, rules.tp))


def encode_audio(cfg: ModelConfig, rules: ShardRules, params, frames):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    enc_cfg = dataclasses.replace(cfg, family="dense",
                                  n_layers=cfg.n_enc_layers)
    x = frames.astype(cfg.compute_dtype)
    pos = jnp.arange(frames.shape[1])
    x, _ = run_stack(enc_cfg, rules, params["enc_layers"], x, pos,
                     causal=False)
    return norm_apply(cfg, x, params["enc_norm"])


def forward_train(cfg: ModelConfig, params, batch: dict,
                  rules: ShardRules) -> tuple[jnp.ndarray, dict]:
    """Token-level LM loss (+ aux).  Handles all families."""
    if cfg.family == "audio":
        enc_out = encode_audio(cfg, rules, params, batch["frames"])
        x = embed_tokens(cfg, params, batch["tokens"])
        pos = jnp.arange(batch["tokens"].shape[1])
        x, stacked = _run_dec_stack_audio(cfg, rules, params, x, pos, enc_out)
    else:
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        x = shard(x, rules.act(None, None))
        pos = jnp.arange(x.shape[1])
        x, stacked = run_stack(cfg, rules, params["layers"], x, pos)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = x[:, batch["patch_embeds"].shape[1]:]

    logits = logits_from_x(cfg, params, x, rules)
    loss, metrics = softmax_xent(logits, batch["labels"])
    if isinstance(stacked, dict) and "moe_aux" in stacked:
        aux = jnp.sum(stacked["moe_aux"])
        loss = loss + aux
        metrics["moe_aux"] = aux
    return loss, metrics


def _run_dec_stack_audio(cfg, rules, params, x, positions, enc_out):
    """Whisper decoder stack: self-attn + cross-attn + mlp, scanned."""
    def body(x, p):
        a_out, _ = _attn_sub(cfg, rules, p, x, positions, causal=True)
        x = x + a_out
        h = norm_apply(cfg, x, p["norm_x"])
        q, _, _ = attn.qkv(cfg, p["xattn"], h, positions)
        # cross kv from encoder output (positions irrelevant -> zeros)
        kx = dense_apply(p["xattn"]["wk"], enc_out)
        vx = dense_apply(p["xattn"]["wv"], enc_out)
        b, se, _ = enc_out.shape
        dh = cfg.head_dim
        kx = kx.reshape(b, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
        vx = vx.reshape(b, se, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
        xo = attn.attend(cfg, q, kx, vx, causal=False)
        bq, hq, sq, _ = xo.shape
        xo = xo.transpose(0, 2, 1, 3).reshape(bq, sq, hq * dh)
        x = x + dense_apply(p["xattn"]["wo"], xo)
        x = x + mlp_mod.apply_dense(cfg, p["mlp"],
                                    norm_apply(cfg, x, p["norm2"]))
        return x, {}

    body = _remat(cfg, body)
    x, stacked = jax.lax.scan(body, x, params["layers"],
                              unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return x, stacked


def softmax_xent(logits, labels, z_coef: float = 1e-4):
    """CE over valid (label >= 0) positions + z-loss, all f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(valid.sum(), 1.0)
    xent = jnp.sum((lse - ll) * valid) / n
    zloss = z_coef * jnp.sum((lse ** 2) * valid) / n
    return xent + zloss, {"xent": xent, "zloss": zloss,
                          "ppl_tokens": n}
